"""Semi-synchronous buffered rounds under heavy-tail stragglers
(EngineConfig.async_k, repro.core.buffer + repro.data.latency).

The synchronous engine pays for its slowest client: a round costs
``1 + max(cohort delays)`` scheduler ticks, and under a heavy-tail latency
model one persistent straggler stalls the whole federation. The buffered
engine dispatches a cohort EVERY tick, folds contributions into a
staleness-weighted server buffer as they arrive, and applies the server
update whenever K contributions have accumulated — throughput is bounded
by the fold rate, not the tail of the latency distribution.

Part 1 — the straggler table. The same DCCO run as a synchronous scan and
as buffered scans at several K, all under the same heavy-tail latency
stream: simulated ticks per server update, probe accuracy, mean applied
staleness, and wire MB side by side.

Part 2 — exactness. With K = cohort, zero latency, and unit staleness the
buffered engine IS the synchronous engine, bit for bit (Eq. 3: the stats
are linear in samples, so the buffer only re-associates the weighted sum).

Run: PYTHONPATH=src python examples/federated_async.py [--rounds 30]
(CI smoke: --rounds 3 --dataset-size 120)
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import DualEncoderConfig, get_config
from repro.core import eval as eval_lib, round_engine
from repro.data import latency as latency_lib, pipeline, synthetic
from repro.models import dual_encoder, resnet
from repro.optim import optimizers as opt_lib


def sync_ticks(lat, rng, num_clients, cpr, rounds):
    """Simulated cost of the SYNC engine under the same latency stream:
    each round waits for its slowest sampled client (1 + max delay ticks).
    Replays the engine's own key derivation, so the cohorts match."""
    total = 0
    for r in range(rounds):
        k_sel, _ = jax.random.split(jax.random.fold_in(rng, r))
        sel = jax.random.choice(k_sel, num_clients, (cpr,), replace=False)
        d = latency_lib.sample_delays(
            lat, jax.random.fold_in(k_sel, latency_lib._LATENCY_SALT),
            sel.astype(jnp.int32))
        total += 1 + int(d.max())
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--dataset-size", type=int, default=600)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--clients-per-round", type=int, default=16)
    ap.add_argument("--latency-tail", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
    key = jax.random.PRNGKey(0)
    params0 = dual_encoder.init_dual_encoder(key, cfg, de)
    imgs, labels = synthetic.synthetic_labeled_images(
        args.dataset_size, args.classes, image_size=cfg.image_size,
        noise=0.5, seed=1)

    def apply(p, batch):
        zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
        zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
        return zf, zg

    def probe(p):
        z = resnet.resnet_forward(cfg, p["tower"], jnp.asarray(imgs))
        cut = int(len(labels) * 0.7)
        return float(eval_lib.ridge_linear_probe(
            z[:cut], jnp.asarray(labels[:cut]), z[cut:],
            jnp.asarray(labels[cut:]), args.classes))

    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=max(args.dataset_size // 2, 8),
        samples_per_client=2, alpha=0.0, seed=0)
    cpr = args.clients_per_round
    lat = latency_lib.LatencyModel("heavytail", horizon=8,
                                   tail=args.latency_tail, seed=0)
    asampler = ds.make_async_round_sampler(cpr, lat)
    rng = jax.random.PRNGKey(7)

    # ---- part 1: sync vs buffered under the same stragglers ------------
    s_ticks = sync_ticks(lat, rng, ds.num_clients, cpr, args.rounds)
    print(f"heavy-tail stragglers (tail={args.latency_tail}, horizon=8), "
          f"{cpr} clients/tick, {args.rounds} ticks:")
    print(f"{'engine':>24s} {'updates':>8s} {'ticks/upd':>10s} "
          f"{'stale':>6s} {'loss':>9s} {'probe':>6s} {'wire MB':>8s}")

    opt = opt_lib.adam(2e-3)
    eng = round_engine.RoundEngine(
        apply, opt, ds.make_round_sampler(cpr),
        round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                  chunk_rounds=min(args.rounds, 25)))
    p, _, m = eng.run(params0, opt.init(params0), rng, args.rounds)
    print(f"{'sync (waits for tail)':>24s} {args.rounds:8d} "
          f"{s_ticks / args.rounds:10.2f} {0.0:6.2f} "
          f"{float(m.loss[-1]):9.3f} {probe(p):6.3f} "
          f"{float(jnp.sum(m.wire_bytes)) / 1e6:8.2f}", flush=True)

    for k in dict.fromkeys((max(cpr // 4, 1), max(cpr // 2, 1))):
        opt = opt_lib.adam(2e-3)
        eng = round_engine.RoundEngine(
            apply, opt, asampler,
            round_engine.EngineConfig(
                algorithm="dcco", lam=5.0,
                chunk_rounds=min(args.rounds, 25), async_k=k,
                staleness_fn="poly", latency=lat))
        p, _, m = eng.run(params0, opt.init(params0), rng, args.rounds)
        upd = int(jnp.sum(m.applied))
        stale = m.staleness[m.applied > 0]
        print(f"{f'buffered K={k} (poly)':>24s} {upd:8d} "
              f"{args.rounds / max(upd, 1):10.2f} "
              f"{float(stale.mean()) if upd else 0.0:6.2f} "
              f"{float(m.loss[-1]):9.3f} {probe(p):6.3f} "
              f"{float(jnp.sum(m.wire_bytes)) / 1e6:8.2f}", flush=True)

    # ---- part 2: K = cohort, zero latency == the sync engine -----------
    opt = opt_lib.adam(2e-3)
    sync = round_engine.RoundEngine(
        apply, opt, ds.make_round_sampler(cpr),
        round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3))
    buf = round_engine.RoundEngine(
        apply, opt, ds.make_async_round_sampler(cpr, None),
        round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3,
                                  async_k=cpr))
    ps, _, _ = sync.run(params0, opt.init(params0), jax.random.PRNGKey(9), 3)
    pb, _, _ = buf.run(params0, opt.init(params0), jax.random.PRNGKey(9), 3)
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(ps), jax.tree.leaves(pb)))
    print(f"\nbuffered K=cohort, zero latency vs sync engine: "
          f"max|diff| = {diff} (Eq. 3 exactness)")


if __name__ == "__main__":
    main()
