"""Quickstart: 60 seconds of federated DCCO on synthetic non-IID clients.

Shows the whole public API surface: config -> dual encoder -> federated
dataset -> scan-compiled DCCO rounds (repro.core.round_engine) ->
linear-probe evaluation, plus the Appendix-A equivalence check against a
centralized step.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import utils
from repro.configs.base import DualEncoderConfig, get_config
from repro.core import eval as eval_lib, fed_sim, round_engine
from repro.data import pipeline, synthetic
from repro.models import dual_encoder, resnet
from repro.optim import optimizers as opt_lib

# 1. model: the paper's WS+GN ResNet dual encoder (reduced)
cfg = get_config("resnet14-cifar", smoke=True)
de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
key = jax.random.PRNGKey(0)
params = dual_encoder.init_dual_encoder(key, cfg, de)


def apply(p, batch):
    zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
    zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
    return zf, zg


# 2. data: synthetic labeled images, Dirichlet(alpha=0) => single-class
#    clients with 2 samples each (the paper's hard setting)
imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16,
                                                  noise=0.5, seed=1)
ds = pipeline.FederatedDataset.build({"images": imgs}, labels,
                                     num_clients=128, samples_per_client=2,
                                     alpha=0.0, seed=0)


def probe(p):
    z = resnet.resnet_forward(cfg, p["tower"], jnp.asarray(imgs))
    return float(eval_lib.ridge_linear_probe(
        z[:400], jnp.asarray(labels[:400]), z[400:], jnp.asarray(labels[400:]), 5))


print(f"random-init probe accuracy: {probe(params):.3f}")

# 3. sanity: one DCCO round == one centralized step (Appendix A).
#    (relative metric: the weight-standardized stem has ~1e4-magnitude
#    gradients, so absolute diffs reflect f32 conditioning, not protocol error)
batch, sizes = ds.round_batch(jax.random.PRNGKey(42), 16)
opt = opt_lib.sgd(0.05)
p_fed, _, _ = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                 batch, sizes, lam=5.0, client_lr=1.0)
union = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
p_cent, _, _ = fed_sim.centralized_step(apply, params, opt.init(params), opt,
                                        union, lam=5.0)
diff = utils.tree_max_abs_diff(p_fed, p_cent)
upd = utils.tree_max_abs_diff(p_fed, params)
print(f"equivalence check: |fed - centralized| / |update| = {diff / upd:.2e}")

# 4. train 30 federated rounds with the scan-compiled engine: client
#    sampling, augmentation, and all rounds of a segment are ONE jitted
#    lax.scan program; per-round metrics stream back per 10-round segment
opt = opt_lib.adam(2e-3)
ecfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=10)
engine = round_engine.RoundEngine(apply, opt, ds.make_round_sampler(16), ecfg)


def report(round_end, carry, m):
    print(f"round {round_end:3d}  loss={float(m.loss[-1]):8.3f}  "
          f"enc_std={float(m.encoding_std[-1]):.3f}")


params, state, metrics = engine.run(params, opt.init(params),
                                    jax.random.PRNGKey(100), 30,
                                    on_segment=report)
print(f"post-pretraining probe accuracy: {probe(params):.3f}")
