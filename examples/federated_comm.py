"""Deployable-regime DCCO: the same federated pretraining run under four
client->server communication channels (repro.comm) — ideal dense uplink,
int8 stochastic-rounding quantization, DP-noised aggregation, and Bernoulli
client dropout — with bytes-on-the-wire and (for DP) epsilon reported next
to linear-probe accuracy.

Every channel sees the identical cohort/augmentation stream (the channel
key is folded off the round key, so sampling is unchanged), which makes the
columns directly comparable: what you pay in bytes or privacy noise vs
what you keep in probe accuracy.

Run: PYTHONPATH=src python examples/federated_comm.py [--rounds 40]
(CI smoke: --rounds 3 --dataset-size 120)
"""
import argparse

import jax
import jax.numpy as jnp

from repro import comm
from repro.configs.base import DualEncoderConfig, get_config
from repro.core import eval as eval_lib, round_engine
from repro.data import pipeline, synthetic
from repro.models import dual_encoder, resnet
from repro.optim import optimizers as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--dataset-size", type=int, default=600)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--clients-per-round", type=int, default=16)
    ap.add_argument("--dp-sigma", type=float, default=0.3)
    ap.add_argument("--dropout-p", type=float, default=0.3)
    args = ap.parse_args()

    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
    key = jax.random.PRNGKey(0)
    params0 = dual_encoder.init_dual_encoder(key, cfg, de)
    imgs, labels = synthetic.synthetic_labeled_images(
        args.dataset_size, args.classes, image_size=cfg.image_size,
        noise=0.5, seed=1)

    def apply(p, batch):
        zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
        zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
        return zf, zg

    def probe(p):
        z = resnet.resnet_forward(cfg, p["tower"], jnp.asarray(imgs))
        cut = int(len(labels) * 0.7)
        return float(eval_lib.ridge_linear_probe(
            z[:cut], jnp.asarray(labels[:cut]), z[cut:],
            jnp.asarray(labels[cut:]), args.classes))

    # single-class 2-sample clients: the paper's hard non-IID setting
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels,
        num_clients=max(args.dataset_size // 2, 8), samples_per_client=2,
        alpha=0.0, seed=0)
    sampler = ds.make_round_sampler(args.clients_per_round)

    channels = [
        ("dense (ideal)", comm.DenseChannel()),
        ("int8 quantized", comm.QuantizedChannel(8)),
        (f"DP sigma={args.dp_sigma}",
         comm.DPGaussianChannel(args.dp_sigma, clip_norm=10.0)),
        (f"dropout p={args.dropout_p}",
         comm.DropoutChannel(args.dropout_p)),
    ]
    print(f"{'channel':>18s} {'loss':>10s} {'probe':>7s} "
          f"{'uplink MB':>10s} {'epsilon':>8s}")
    for name, ch in channels:
        opt = opt_lib.adam(2e-3)
        ecfg = round_engine.EngineConfig(
            algorithm="dcco", lam=5.0,
            chunk_rounds=min(args.rounds, 25), channel=ch)
        eng = round_engine.RoundEngine(apply, opt, sampler, ecfg)
        p, _, m = eng.run(params0, opt.init(params0),
                          jax.random.PRNGKey(7), args.rounds)
        acct = getattr(ch, "accountant", None)
        eps = f"{acct.epsilon():8.1f}" if acct is not None else "     inf"
        print(f"{name:>18s} {float(m.loss[-1]):10.3f} {probe(p):7.3f} "
              f"{float(jnp.sum(m.wire_bytes)) / 1e6:10.2f} {eps}",
              flush=True)
    print(f"{'random init':>18s} {'-':>10s} {probe(params0):7.3f}")


if __name__ == "__main__":
    main()
