"""Hierarchical aggregation + streaming mega-cohorts (repro.hierarchy).

Part 1 — the two-level wire. The same federated DCCO run under three
aggregation topologies: flat dense (every client straight to the server),
a two-level tree with an int8 client->edge uplink and a dense edge->server
backbone, and the same tree with edge outages (an edge-hop DropoutChannel
— a failing edge takes ALL its clients down at once, the regional-outage
failure mode flat dropout cannot model). Per-hop uplink bytes are printed
next to probe accuracy; the dense-dense tree is bit-identical to flat
aggregation (Eq. 3: the payloads are linear in samples, so the summation
tree is semantically invisible).

Part 2 — the memory-free cohort knob. One round of an N-client cohort is
streamed through the engine in fixed-size chunks (EngineConfig.
cohort_chunk): peak batch memory is O(chunk) while the cohort grows
64 -> N, the regime of cross-device populations where rounds draw from
thousands of tiny clients.

Run: PYTHONPATH=src python examples/federated_hierarchy.py [--rounds 30]
(CI smoke: --rounds 3 --dataset-size 120 --mega-cohort 64)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import comm, hierarchy
from repro.configs.base import DualEncoderConfig, get_config
from repro.core import eval as eval_lib, round_engine
from repro.data import pipeline, synthetic
from repro.models import dual_encoder, resnet
from repro.optim import optimizers as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--dataset-size", type=int, default=600)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--clients-per-round", type=int, default=16)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--edge-dropout", type=float, default=0.25)
    ap.add_argument("--mega-cohort", type=int, default=256,
                    help="clients/round for the streaming demo")
    ap.add_argument("--cohort-chunk", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
    key = jax.random.PRNGKey(0)
    params0 = dual_encoder.init_dual_encoder(key, cfg, de)
    imgs, labels = synthetic.synthetic_labeled_images(
        args.dataset_size, args.classes, image_size=cfg.image_size,
        noise=0.5, seed=1)

    def apply(p, batch):
        zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
        zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
        return zf, zg

    def probe(p):
        z = resnet.resnet_forward(cfg, p["tower"], jnp.asarray(imgs))
        cut = int(len(labels) * 0.7)
        return float(eval_lib.ridge_linear_probe(
            z[:cut], jnp.asarray(labels[:cut]), z[cut:],
            jnp.asarray(labels[cut:]), args.classes))

    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=max(args.dataset_size // 2, 8),
        samples_per_client=2, alpha=0.0, seed=0)
    sampler = ds.make_round_sampler(args.clients_per_round)
    # a round samples without replacement: the mega cohort is capped at
    # the client population (and kept a multiple of the chunk)
    mega = min(args.mega_cohort, ds.num_clients)
    mega -= mega % min(args.cohort_chunk, mega)

    # ---- part 1: aggregation topologies --------------------------------
    topologies = [
        ("flat dense", comm.DenseChannel()),
        (f"{args.edges} edges, int8 uplink", hierarchy.HierarchicalChannel(
            args.edges, client_channel=comm.QuantizedChannel(8))),
        (f"{args.edges} edges, outage p={args.edge_dropout}",
         hierarchy.HierarchicalChannel(
             args.edges, client_channel=comm.QuantizedChannel(8),
             edge_channel=comm.DropoutChannel(args.edge_dropout))),
    ]
    print(f"{'topology':>28s} {'loss':>9s} {'probe':>6s} "
          f"{'client->edge MB':>16s} {'edge->server MB':>16s}")
    for name, ch in topologies:
        opt = opt_lib.adam(2e-3)
        ecfg = round_engine.EngineConfig(
            algorithm="dcco", lam=5.0,
            chunk_rounds=min(args.rounds, 25), channel=ch)
        eng = round_engine.RoundEngine(apply, opt, sampler, ecfg)
        p, _, m = eng.run(params0, opt.init(params0),
                          jax.random.PRNGKey(7), args.rounds)
        total_mb = float(jnp.sum(m.wire_bytes)) / 1e6
        if isinstance(ch, hierarchy.HierarchicalChannel):
            # per-hop split of the measured total from the static payload
            # widths: K client payloads vs E edge payloads per phase (an
            # edge outage shrinks both hops by the same survival factor,
            # so the split is participation-independent)
            tmpl = {"x": jnp.zeros((64,))}
            cb = args.clients_per_round * \
                ch.client_channel.payload_bytes(tmpl)
            eb = args.edges * ch.edge_channel.payload_bytes(tmpl)
            frac_c = cb / (cb + eb)
            mb_c, mb_e = total_mb * frac_c, total_mb * (1 - frac_c)
        else:
            mb_c, mb_e = total_mb, 0.0
        print(f"{name:>28s} {float(m.loss[-1]):9.3f} {probe(p):6.3f} "
              f"{mb_c:16.2f} {mb_e:16.2f}", flush=True)

    # exactness: a dense-dense tree IS flat aggregation, bit for bit
    opt = opt_lib.adam(2e-3)
    flat = round_engine.RoundEngine(
        apply, opt, sampler,
        round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3))
    tree = round_engine.RoundEngine(
        apply, opt, sampler,
        round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3,
                                  channel=hierarchy.HierarchicalChannel(
                                      args.edges)))
    pf, _, _ = flat.run(params0, opt.init(params0), jax.random.PRNGKey(9), 3)
    pt, _, _ = tree.run(params0, opt.init(params0), jax.random.PRNGKey(9), 3)
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(pf), jax.tree.leaves(pt)))
    print(f"dense two-level tree vs flat aggregation: max|diff| = {diff} "
          f"(Eq. 3 exactness)")

    # ---- part 2: streaming mega-cohort ---------------------------------
    print(f"\nstreaming {mega} clients/round in chunks of "
          f"{args.cohort_chunk} (peak batch memory O(chunk)):")

    def chunk_aligned(cohort):
        """Largest chunk-multiple cohort <= ``cohort`` (>= one chunk)."""
        chunk = min(args.cohort_chunk, cohort)
        return max(cohort - cohort % chunk, chunk)

    for cohort in dict.fromkeys((chunk_aligned(min(64, mega)), mega)):
        opt = opt_lib.adam(2e-3)
        ecfg = round_engine.EngineConfig(
            algorithm="dcco", lam=5.0, chunk_rounds=1,
            cohort_chunk=min(args.cohort_chunk, cohort))
        eng = round_engine.RoundEngine(
            apply, opt, ds.make_streaming_sampler(
                cohort, min(args.cohort_chunk, cohort)), ecfg)
        t0 = time.perf_counter()
        p, _, m = eng.run(params0, opt.init(params0),
                          jax.random.PRNGKey(7), 1)
        jax.block_until_ready(m.loss)
        print(f"  cohort {cohort:5d}: loss={float(m.loss[-1]):8.3f} "
              f"round_time={time.perf_counter() - t0:6.2f}s "
              f"(incl. compile)", flush=True)


if __name__ == "__main__":
    main()
