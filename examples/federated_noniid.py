"""Server optimization & drift correction on label-sharded non-IID clients.

The paper's hard setting — 2-sample single-class clients (alpha=0 label
sharding) — is exactly where a fixed server average struggles: per-round
pseudo-gradients are noisy and badly scaled, and with multiple local steps
the client updates drift apart. This scenario trains the same DCCO engine
run under different repro.server strategies and reports linear-probe
accuracy:

  fedavg_sgd      — plain FedAvg: the server applies the average delta
                    (SGD at server lr 1.0); the baseline.
  fedavgm         — server heavy-ball momentum.
  fedadam         — Reddi-style adaptive server optimizer (tau-damped
                    per-parameter preconditioning of the pseudo-gradient).
  fedadam+scaffold— adaptivity on the server plus SCAFFOLD control
                    variates; under cohort sampling the per-slot variates
                    reshape the update even at one local step.

Every row sees the identical cohort/augmentation stream, differing only in
the server/drift strategy, so the probe columns are directly comparable.
With the default small cohorts (8 clients/round of 300 — the pseudo-
gradient-noise regime server adaptivity targets), every strategy beats
plain FedAvg on probe accuracy within 50 rounds on CPU (measured at the
default seeds: fedadam +0.150, fedavgm +0.083, fedadam+scaffold +0.033
over the 0.589 baseline; random init probes 0.539).

Run: PYTHONPATH=src python examples/federated_noniid.py [--rounds 50]
(CI smoke: --rounds 3 --dataset-size 120)
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import DualEncoderConfig, get_config
from repro.core import eval as eval_lib, round_engine
from repro.data import pipeline, synthetic
from repro.models import dual_encoder, resnet
from repro.server import get_server_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--dataset-size", type=int, default=600)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--clients-per-round", type=int, default=8,
                    help="small cohorts = noisy pseudo-gradients, the "
                         "regime server adaptivity targets")
    ap.add_argument("--noise", type=float, default=1.0,
                    help="synthetic dataset difficulty")
    args = ap.parse_args()

    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
    key = jax.random.PRNGKey(0)
    params0 = dual_encoder.init_dual_encoder(key, cfg, de)
    imgs, labels = synthetic.synthetic_labeled_images(
        args.dataset_size, args.classes, image_size=cfg.image_size,
        noise=args.noise, seed=1)

    def apply(p, batch):
        zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
        zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
        return zf, zg

    def probe(p):
        z = resnet.resnet_forward(cfg, p["tower"], jnp.asarray(imgs))
        cut = int(len(labels) * 0.7)
        return float(eval_lib.ridge_linear_probe(
            z[:cut], jnp.asarray(labels[:cut]), z[cut:],
            jnp.asarray(labels[cut:]), args.classes))

    # alpha=0: every client holds 2 samples of ONE class — the paper's
    # hard label-sharded split
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels,
        num_clients=max(args.dataset_size // 2, 8), samples_per_client=2,
        alpha=0.0, seed=0)
    sampler = ds.make_round_sampler(args.clients_per_round)

    rows = [
        ("fedavg_sgd (baseline)",
         lambda: get_server_update("fedavg_sgd", server_lr=1.0), {}),
        ("fedavgm",
         lambda: get_server_update("fedavgm", server_lr=0.5), {}),
        ("fedadam",
         lambda: get_server_update("fedadam", server_lr=3e-2, tau=1e-2), {}),
        ("fedadam+scaffold",
         lambda: get_server_update("fedadam", server_lr=1e-2, tau=1e-2),
         {"scaffold": True}),
    ]
    print(f"label-sharded non-IID split: "
          f"{ds.num_clients} single-class 2-sample clients, "
          f"{args.clients_per_round}/round, {args.rounds} rounds")
    print(f"{'strategy':>28s} {'loss':>10s} {'probe':>7s}")
    base_acc = None
    for name, make_su, extra in rows:
        su = make_su()
        ecfg = round_engine.EngineConfig(
            algorithm="dcco", lam=5.0,
            chunk_rounds=min(args.rounds, 25), server_update=su, **extra)
        eng = round_engine.RoundEngine(apply, su, sampler, ecfg)
        p, _, m = eng.run(params0, su.init(params0),
                          jax.random.PRNGKey(7), args.rounds)
        acc = probe(p)
        if base_acc is None:
            base_acc = acc
        print(f"{name:>28s} {float(m.loss[-1]):10.3f} {acc:7.3f}"
              f"  ({acc - base_acc:+.3f} vs baseline)", flush=True)
    print(f"{'random init':>28s} {'-':>10s} {probe(params0):7.3f}")


if __name__ == "__main__":
    main()
